"""ResidualAttention — Pallas kernel for the disaggregated KV cache (paper Alg. 1).

The kernel fuses KV-cache reconstruction into the attention loop so the full
K/V are never materialized in HBM:

  Stage 1 (per key block, on-chip): K_lora = RoPE(K_res @ B_k);  K = K_base + K_lora
  Stage 2: online-softmax attention with *two* accumulators:
             acc   += P @ V_base      (full width head_dim)
             acc_r += P @ V_res       (width r only)
  Stage 3 (epilogue, once): O = (acc + acc_r @ B_v) / l
           -- the V up-projection is hoisted out of the loop via matrix
              associativity (paper Eq. 4).

TPU adaptation (DESIGN.md §2): the grid iterates (query-block, head); B_k/B_v
are pinned whole in VMEM (they are r x hd slices, a few KB); key blocks are
streamed with `fori_loop` + dynamic slices over refs that the BlockSpec maps
into VMEM. `interpret=True` is mandatory on this CPU-only image — real TPU
lowering emits Mosaic custom-calls the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _rotate_half(x):
    half = x.shape[-1] // 2
    return jnp.concatenate([-x[..., half:], x[..., :half]], axis=-1)


def _kernel(
    q_ref,       # [bq, hd]        query block for this (qb, head)
    kb_ref,      # [s, hd]         base keys for this head's kv group
    vb_ref,      # [s, hd]
    kr_ref,      # [s, r]          residual keys (shared across heads)
    vr_ref,      # [s, r]
    bk_ref,      # [r, hd]         K up-projection slice for this kv head
    bv_ref,      # [r, hd]
    qpos_ref,    # [bq]            absolute positions of queries
    sin_ref,     # [s, hd]
    cos_ref,     # [s, hd]
    o_ref,       # [bq, hd]        output block
    *,
    block_k: int,
    seq_len: int,
    sm_scale: float,
):
    bq, hd = q_ref.shape
    r = kr_ref.shape[-1]
    nblocks = seq_len // block_k

    q = q_ref[...].astype(jnp.float32)
    qpos = qpos_ref[...]
    # Stage-0: pin the tiny LoRA up-projections in VMEM for the whole kernel
    # (paper Alg. 1 line 3: "Load B_k, B_v to SRAM").
    bk = bk_ref[...].astype(jnp.float32)   # [r, hd]
    bv = bv_ref[...].astype(jnp.float32)   # [r, hd]

    def body(nb, carry):
        acc, acc_r, m, l = carry
        kslice = pl.dslice(nb * block_k, block_k)

        # ---- Stage 1: on-the-fly key reconstruction with deferred RoPE ----
        kb = kb_ref[kslice, :].astype(jnp.float32)       # [bk, hd]
        kr = kr_ref[kslice, :].astype(jnp.float32)       # [bk, r]
        sin = sin_ref[kslice, :].astype(jnp.float32)     # [bk, hd]
        cos = cos_ref[kslice, :].astype(jnp.float32)
        k_lora = kr @ bk                                  # [bk, hd]  (MXU)
        k_lora = k_lora * cos + _rotate_half(k_lora) * sin
        k = kb + k_lora

        # ---- Stage 2: separate attention accumulation (base / residual) ----
        s_blk = (q @ k.T) * sm_scale                      # [bq, bk]
        kpos = nb * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = kpos[None, :] <= qpos[:, None]
        s_blk = jnp.where(mask, s_blk, NEG_INF)

        m_new = jnp.maximum(m, s_blk.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s_blk - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)

        vb = vb_ref[kslice, :].astype(jnp.float32)        # [bk, hd]
        vr = vr_ref[kslice, :].astype(jnp.float32)        # [bk, r]
        acc = acc * alpha[:, None] + p @ vb               # [bq, hd]
        acc_r = acc_r * alpha[:, None] + p @ vr           # [bq, r]
        return acc, acc_r, m_new, l_new

    acc0 = jnp.zeros((bq, hd), jnp.float32)
    accr0 = jnp.zeros((bq, r), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, acc_r, m, l = jax.lax.fori_loop(0, nblocks, body, (acc0, accr0, m0, l0))

    # ---- Stage 3: fuse via matrix associativity (Eq. 4) ----
    acc_final = acc + acc_r @ bv                          # [bq, hd]
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (padded queries)
    o_ref[...] = (acc_final / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "interpret"),
)
def residual_attention(
    q,        # [m, h, hd]    rotated queries
    k_base,   # [s, kh, hd]   rotated base keys (bCache)
    v_base,   # [s, kh, hd]
    k_res,    # [s, r]        un-rotated residual keys (rCache)
    v_res,    # [s, r]
    b_k,      # [r, kh, hd]   LoRA up-projection, scale folded in
    b_v,      # [r, kh, hd]
    q_pos,    # [m] int32
    sin,      # [s, hd]
    cos,      # [s, hd]
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """Fused attention over a disaggregated KV cache. Returns [m, h, hd].

    Requires s % block_k == 0; m is padded internally to block_q. GQA is
    expressed through the grid: query head i reads kv head i // (h // kh).
    """
    m, h, hd = q.shape
    s, kh, _ = k_base.shape
    r = k_res.shape[-1]
    if s % block_k != 0:
        raise ValueError(f"seq_len {s} must be divisible by block_k {block_k}")
    group = h // kh

    block_q = min(block_q, max(m, 1))
    pad_m = (-m) % block_q
    if pad_m:
        q = jnp.pad(q, ((0, pad_m), (0, 0), (0, 0)))
        # Padded queries get position -1: every key is masked; the kernel's
        # l==0 guard keeps the division finite and rows are sliced off below.
        q_pos = jnp.pad(q_pos, (0, pad_m), constant_values=-1)
    m_padded = q.shape[0]
    nq = m_padded // block_q

    sm_scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(
        _kernel, block_k=block_k, seq_len=s, sm_scale=sm_scale
    )

    grid = (nq, h)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, None, hd), lambda qb, hh: (qb, hh, 0)),   # q
            pl.BlockSpec((s, None, hd), lambda qb, hh, g=group: (0, hh // g, 0)),  # kb
            pl.BlockSpec((s, None, hd), lambda qb, hh, g=group: (0, hh // g, 0)),  # vb
            pl.BlockSpec((s, r), lambda qb, hh: (0, 0)),                  # kr
            pl.BlockSpec((s, r), lambda qb, hh: (0, 0)),                  # vr
            pl.BlockSpec((r, None, hd), lambda qb, hh, g=group: (0, hh // g, 0)),  # bk
            pl.BlockSpec((r, None, hd), lambda qb, hh, g=group: (0, hh // g, 0)),  # bv
            pl.BlockSpec((block_q,), lambda qb, hh: (qb,)),               # qpos
            pl.BlockSpec((s, hd), lambda qb, hh: (0, 0)),                 # sin
            pl.BlockSpec((s, hd), lambda qb, hh: (0, 0)),                 # cos
        ],
        out_specs=pl.BlockSpec((block_q, None, hd), lambda qb, hh: (qb, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((m_padded, h, hd), q.dtype),
        interpret=interpret,
    )(
        q,
        k_base,
        v_base,
        k_res,
        v_res,
        b_k,
        b_v,
        q_pos,
        sin,
        cos,
    )
    return out[:m]
