"""Pure-jnp reference oracle for ResidualAttention.

This module is the correctness anchor of the whole stack: the Pallas kernel
(`residual_attention.py`), the L2 model, and (transitively, through the AOT
artifacts) the Rust request path are all validated against these functions.

Disaggregated KV cache layout (paper §5.1):
  bCache:  K_base = RoPE(x W_k) and V_base = x W_v   -- full-width, shared
  rCache:  K_res  = x A_k       and V_res  = x A_v   -- rank-r, per adapter
Reconstruction (exact, because RoPE is linear per position):
  K = K_base + RoPE(K_res @ B_k)
  V = V_base + V_res @ B_v
"""

from __future__ import annotations

import jax.numpy as jnp


def rope_tables(s: int, head_dim: int, theta: float = 10000.0, dtype=jnp.float32):
    """Return (sin, cos) tables of shape [s, head_dim].

    Uses the half-split convention: dimension i pairs with i + head_dim/2,
    frequencies are theta ** (-2i / head_dim) for i in [0, head_dim/2).
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(s, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]  # [s, half]
    sin = jnp.concatenate([jnp.sin(ang), jnp.sin(ang)], axis=-1)
    cos = jnp.concatenate([jnp.cos(ang), jnp.cos(ang)], axis=-1)
    return sin.astype(dtype), cos.astype(dtype)


def apply_rope(x, sin, cos):
    """Rotate `x` [..., s, head_dim] by per-position tables broadcastable to x.

    rotate_half convention: rot(x) = x * cos + rotate_half(x) * sin where
    rotate_half([a, b]) = [-b, a] on the two half-splits of the last dim.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate([-x2, x1], axis=-1)
    return x * cos + rotated * sin


def reconstruct_k(k_base, k_res, b_k, sin, cos):
    """K = K_base + RoPE(K_res @ B_k).

    k_base: [s, kh, hd] (already rotated), k_res: [s, r],
    b_k: [r, kh, hd] (LoRA scale pre-folded), sin/cos: [s, hd].
    """
    k_lora = jnp.einsum("sr,rkh->skh", k_res, b_k)  # [s, kh, hd]
    k_lora = apply_rope(k_lora, sin[:, None, :], cos[:, None, :])
    return k_base + k_lora


def reconstruct_v(v_base, v_res, b_v):
    """V = V_base + V_res @ B_v (no RoPE on values)."""
    return v_base + jnp.einsum("sr,rkh->skh", v_res, b_v)


def residual_attention_ref(
    q,          # [m, h, hd]   queries (already rotated)
    k_base,     # [s, kh, hd]  rotated base keys
    v_base,     # [s, kh, hd]
    k_res,      # [s, r]       un-rotated low-rank key residuals
    v_res,      # [s, r]
    b_k,        # [r, kh, hd]  LoRA up-projection (scale folded in)
    b_v,        # [r, kh, hd]
    q_pos,      # [m] int32    absolute position of each query
    sin,        # [s, hd]
    cos,        # [s, hd]
):
    """Exact attention over the disaggregated cache.

    Causal/padding mask: key slot j is visible to query i iff j <= q_pos[i].
    Cache slots are laid out so that slot index == absolute token position;
    garbage slots beyond the filled region sit at positions > max(q_pos) and
    are therefore masked out by the same comparison.
    """
    m, h, hd = q.shape
    s, kh, _ = k_base.shape
    group = h // kh

    k = reconstruct_k(k_base, k_res, b_k, sin, cos)  # [s, kh, hd]
    v = reconstruct_v(v_base, v_res, b_v)            # [s, kh, hd]

    # Expand GQA kv heads to query heads.
    k = jnp.repeat(k, group, axis=1)  # [s, h, hd]
    v = jnp.repeat(v, group, axis=1)

    scale = 1.0 / jnp.sqrt(jnp.array(hd, dtype=jnp.float32))
    logits = jnp.einsum("mhd,shd->hms", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale

    kpos = jnp.arange(s, dtype=jnp.int32)
    mask = kpos[None, :] <= q_pos[:, None]  # [m, s]
    logits = jnp.where(mask[None, :, :], logits, -1e30)

    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hms,shd->mhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def unified_attention_ref(q, k, v, q_pos):
    """Standard attention over a monolithic cache (baseline oracle).

    q: [m, h, hd], k/v: [s, kh, hd] fully merged + rotated.
    """
    s, kh, hd = k.shape
    zeros_res = jnp.zeros((s, 1), dtype=k.dtype)
    zeros_b = jnp.zeros((1, kh, hd), dtype=k.dtype)
    sin = jnp.zeros((s, hd), dtype=k.dtype)
    cos = jnp.ones((s, hd), dtype=k.dtype)
    return residual_attention_ref(
        q, k, v, zeros_res, zeros_res, zeros_b, zeros_b, q_pos, sin, cos
    )
