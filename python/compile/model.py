"""L2: multi-LoRA transformer over the disaggregated KV cache.

A Llama/Qwen-style decoder (RMSNorm, RoPE, GQA, SwiGLU, optional QKV bias)
with LoRA adapters on the q/k/v/o projections, selected per request from an
*adapter bank* passed as arguments (so the Rust runtime uploads the bank once
as PJRT buffers and selects adapters by index in-graph).

Two entrypoints are AOT-lowered (see aot.py):
  - prefill: one chunk of `C` tokens for a single sequence
  - decode:  one token for each of `B` sequences (vmap of the row function)

Both read/write the disaggregated cache layout of paper §5.1 and call the
L1 Pallas `residual_attention` kernel for every attention. The unified
baselines run through the *same* artifacts by storing merged K/V in the
base-layout cache and passing zero residuals (kernel reduces exactly to
standard attention — tested in test_kernel.py).

Weights are explicit positional arguments in `param_names()` order; the
Rust side replays the same order from `manifest.json`.
"""

from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.ref import apply_rope, rope_tables
from .kernels.residual_attention import residual_attention

# ---------------------------------------------------------------------------
# parameter schema
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> List[tuple]:
    """Ordered (name, shape) for all base-model parameters.

    Bias vectors are always present (zero when cfg.qkv_bias is False) so that
    all three models share one artifact I/O contract.
    """
    d, qw, kvw, ff, v = cfg.d_model, cfg.q_width, cfg.kv_width, cfg.d_ff, cfg.vocab
    specs = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.norm1", (d,)),
            (f"l{i}.wq", (d, qw)),
            (f"l{i}.bq", (qw,)),
            (f"l{i}.wk", (d, kvw)),
            (f"l{i}.bk", (kvw,)),
            (f"l{i}.wv", (d, kvw)),
            (f"l{i}.bv", (kvw,)),
            (f"l{i}.wo", (qw, d)),
            (f"l{i}.norm2", (d,)),
            (f"l{i}.wg", (d, ff)),
            (f"l{i}.wu", (d, ff)),
            (f"l{i}.wd", (ff, d)),
        ]
    specs += [("normf", (d,)), ("lm_head", (d, v))]
    return specs


def bank_specs(cfg: ModelConfig) -> List[tuple]:
    """Ordered (name, shape) for the stacked adapter bank.

    A*: down-projections (store x@A as rCache); B*: up-projections with the
    LoRA scale alpha/r folded in at init. Rank is padded to cfg.rank_max;
    adapters with a smaller effective rank have zero tail columns/rows.
    """
    na, nl, d, r = cfg.n_adapters, cfg.n_layers, cfg.d_model, cfg.rank_max
    qw, kvw = cfg.q_width, cfg.kv_width
    return [
        ("bank.aq", (na, nl, d, r)),
        ("bank.bq", (na, nl, r, qw)),
        ("bank.ak", (na, nl, d, r)),
        ("bank.bk", (na, nl, r, kvw)),
        ("bank.av", (na, nl, d, r)),
        ("bank.bv", (na, nl, r, kvw)),
        ("bank.ao", (na, nl, qw, r)),
        ("bank.bo", (na, nl, r, d)),
    ]


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Seeded random init, scaled for a stable residual stream."""
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".norm1", ".norm2")) or name == "normf":
            out[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".bq", ".bk", ".bv")):
            if cfg.qkv_bias:
                out[name] = jax.random.normal(sub, shape, jnp.float32) * 0.02
            else:
                out[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = fan_in ** -0.5
            out[name] = jax.random.normal(sub, shape, jnp.float32) * scale
    return out


def init_bank(cfg: ModelConfig, rank: int = 16, seed: int = 1,
              lora_alpha_mult: float = 2.0) -> Dict[str, jax.Array]:
    """Seeded adapter bank. Each of the cfg.n_adapters slots is a distinct
    adapter of effective `rank`; tails up to rank_max are zero. The LoRA
    scale alpha/r (= lora_alpha_mult) is folded into the B matrices."""
    assert rank <= cfg.rank_max
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, shape in bank_specs(cfg):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, shape, jnp.float32)
        if name.startswith("bank.a"):
            w = w * (shape[-2] ** -0.5)          # fan-in of the down-proj
            w = w.at[..., rank:].set(0.0)        # pad rank to rank_max
        else:
            # Trained-adapter-like magnitude: LoRA deltas are a few percent
            # of the base activation norm (Hu et al.), not O(1) — this is
            # what bounds the paper's cross-agent x divergence (Fig. 5b).
            w = w * 0.012 * lora_alpha_mult
            w = w.at[..., rank:, :].set(0.0)
        out[name] = w
    return out


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------


def _rmsnorm(x, w, eps=1e-6):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def _lora(h, a, b, on):
    """h @ A @ B, gated by `on` (0.0 disables the adapter entirely)."""
    return ((h @ a) @ b) * on


def forward_chunk(
    cfg: ModelConfig,
    params: Dict[str, jax.Array],
    bank: Dict[str, jax.Array],
    tokens,       # i32[C]
    cache_len,    # i32[] -- number of already-cached tokens (= first position)
    adapter_id,   # i32[]
    adapter_on,   # f32[]
    kb,           # f32[L, S, KH, HD] rotated base keys
    vb,           # f32[L, S, KH, HD]
    kr,           # f32[L, S, R]
    vr,           # f32[L, S, R]
    *,
    interpret: bool = True,
):
    """Process one chunk of C tokens at positions [cache_len, cache_len+C).

    Returns (logits[C,V], kb_new[L,C,KH,HD], vb_new, kr_new[L,C,R], vr_new,
             km_new[L,C,KH,HD], vm_new, xs[L,C,d]).
    The padded cache arrays are updated in-graph only for attention; the
    caller persists the returned chunk tensors into its pools.
    """
    C = tokens.shape[0]
    L, S = cfg.n_layers, cfg.s_max
    KH, HD, H = cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
    R = cfg.rank_max

    sin_t, cos_t = rope_tables(S, HD, cfg.rope_theta)        # [S, HD]
    pos = cache_len + jnp.arange(C, dtype=jnp.int32)          # [C]
    # Per-chunk rope slices; positions are always < S by construction.
    sin_c = jnp.take(sin_t, pos, axis=0)                      # [C, HD]
    cos_c = jnp.take(cos_t, pos, axis=0)

    def pick(name):
        return jnp.take(bank[name], adapter_id, axis=0)       # [L, ...]

    aq, bq = pick("bank.aq"), pick("bank.bq")
    ak, bk_up = pick("bank.ak"), pick("bank.bk")
    av, bv_up = pick("bank.av"), pick("bank.bv")
    ao, bo = pick("bank.ao"), pick("bank.bo")

    x = jnp.take(params["embed"], tokens, axis=0)             # [C, d]
    kb_out, vb_out, kr_out, vr_out, km_out, vm_out, xs = [], [], [], [], [], [], []

    for i in range(L):
        h = _rmsnorm(x, params[f"l{i}.norm1"])

        q = h @ params[f"l{i}.wq"] + params[f"l{i}.bq"] + _lora(h, aq[i], bq[i], adapter_on)
        k_base = h @ params[f"l{i}.wk"] + params[f"l{i}.bk"]  # bias lives in bCache
        v_base = h @ params[f"l{i}.wv"] + params[f"l{i}.bv"]
        # rCache truncates at the down-projection (paper §5.1); gate by
        # adapter_on so a zeroed adapter reproduces the pure base model.
        k_res = (h @ ak[i]) * adapter_on                      # [C, R]
        v_res = (h @ av[i]) * adapter_on

        q = q.reshape(C, H, HD)
        q = apply_rope(q, sin_c[:, None, :], cos_c[:, None, :])
        k_base = k_base.reshape(C, KH, HD)
        k_base = apply_rope(k_base, sin_c[:, None, :], cos_c[:, None, :])
        v_base = v_base.reshape(C, KH, HD)

        # Write the chunk into the padded cache (slot == absolute position).
        kb_l = jax.lax.dynamic_update_slice(kb[i], k_base, (cache_len, 0, 0))
        vb_l = jax.lax.dynamic_update_slice(vb[i], v_base, (cache_len, 0, 0))
        kr_l = jax.lax.dynamic_update_slice(kr[i], k_res, (cache_len, 0))
        vr_l = jax.lax.dynamic_update_slice(vr[i], v_res, (cache_len, 0))

        bk_i = bk_up[i].reshape(R, KH, HD)
        bv_i = bv_up[i].reshape(R, KH, HD)
        attn = residual_attention(
            q, kb_l, vb_l, kr_l, vr_l, bk_i, bv_i, pos, sin_t, cos_t,
            interpret=interpret,
        )                                                     # [C, H, HD]

        attn = attn.reshape(C, H * HD)
        o = attn @ params[f"l{i}.wo"] + _lora(attn, ao[i], bo[i], adapter_on)
        x = x + o

        h2 = _rmsnorm(x, params[f"l{i}.norm2"])
        mlp = (jax.nn.silu(h2 @ params[f"l{i}.wg"]) * (h2 @ params[f"l{i}.wu"]))
        x = x + mlp @ params[f"l{i}.wd"]

        # Merged (monolithic) chunk K/V for the unified-cache baselines.
        k_lora = (k_res @ bk_up[i]).reshape(C, KH, HD)
        k_lora = apply_rope(k_lora, sin_c[:, None, :], cos_c[:, None, :])
        km = k_base + k_lora
        vm = v_base + (v_res @ bv_up[i]).reshape(C, KH, HD)

        kb_out.append(k_base); vb_out.append(v_base)
        kr_out.append(k_res); vr_out.append(v_res)
        km_out.append(km); vm_out.append(vm)
        xs.append(x)

    logits = _rmsnorm(x, params["normf"]) @ params["lm_head"]  # [C, V]
    stack = lambda t: jnp.stack(t, axis=0)
    return (
        logits,
        stack(kb_out), stack(vb_out),
        stack(kr_out), stack(vr_out),
        stack(km_out), stack(vm_out),
        stack(xs),
    )


# ---------------------------------------------------------------------------
# AOT entrypoints
# ---------------------------------------------------------------------------


def make_prefill_fn(cfg: ModelConfig, interpret: bool = True):
    """Returns f(*params, *bank, tokens, cache_len, adapter_id, adapter_on,
    kb, vb, kr, vr) -> 8-tuple; argument order matches manifest.json."""
    pnames = [n for n, _ in param_specs(cfg)]
    bnames = [n for n, _ in bank_specs(cfg)]

    def fn(*args):
        params = dict(zip(pnames, args[: len(pnames)]))
        bank = dict(zip(bnames, args[len(pnames): len(pnames) + len(bnames)]))
        rt = args[len(pnames) + len(bnames):]
        tokens, cache_len, adapter_id, adapter_on, kb, vb, kr, vr = rt
        return forward_chunk(
            cfg, params, bank, tokens, cache_len, adapter_id, adapter_on,
            kb, vb, kr, vr, interpret=interpret,
        )

    return fn


def make_decode_fn(cfg: ModelConfig, batch: int, interpret: bool = True):
    """Batched single-token decode: vmap of the chunk function with C=1.

    f(*params, *bank, tokens[B], cache_lens[B], adapter_ids[B],
      adapter_on[B], kb[B,L,S,KH,HD], vb, kr[B,L,S,R], vr)
      -> (logits[B,V], kb_new[B,L,KH,HD], vb_new, kr_new[B,L,R], vr_new,
          km_new[B,L,KH,HD], vm_new)
    """
    pnames = [n for n, _ in param_specs(cfg)]
    bnames = [n for n, _ in bank_specs(cfg)]

    def row(params, bank, token, cache_len, adapter_id, adapter_on, kb, vb, kr, vr):
        out = forward_chunk(
            cfg, params, bank, token[None], cache_len, adapter_id, adapter_on,
            kb, vb, kr, vr, interpret=interpret,
        )
        logits, kbn, vbn, krn, vrn, kmn, vmn, _xs = out
        squeeze = lambda t: t[:, 0]  # drop the C=1 axis -> [L, ...]
        return (
            logits[0],
            squeeze(kbn), squeeze(vbn), squeeze(krn), squeeze(vrn),
            squeeze(kmn), squeeze(vmn),
        )

    def fn(*args):
        params = dict(zip(pnames, args[: len(pnames)]))
        bank = dict(zip(bnames, args[len(pnames): len(pnames) + len(bnames)]))
        tokens, cache_lens, adapter_ids, adapter_on, kb, vb, kr, vr = args[
            len(pnames) + len(bnames):
        ]
        return jax.vmap(
            functools.partial(row, params, bank),
        )(tokens, cache_lens, adapter_ids, adapter_on, kb, vb, kr, vr)

    return fn


def runtime_input_specs(cfg: ModelConfig, kind: str, batch: int = 1):
    """Shapes/dtypes of the runtime (non-weight) inputs, manifest order."""
    L, S, KH, HD, R = (
        cfg.n_layers, cfg.s_max, cfg.n_kv_heads, cfg.head_dim, cfg.rank_max,
    )
    if kind == "prefill":
        C = cfg.chunk
        return [
            ("tokens", (C,), "i32"),
            ("cache_len", (), "i32"),
            ("adapter_id", (), "i32"),
            ("adapter_on", (), "f32"),
            ("kb", (L, S, KH, HD), "f32"),
            ("vb", (L, S, KH, HD), "f32"),
            ("kr", (L, S, R), "f32"),
            ("vr", (L, S, R), "f32"),
        ]
    assert kind == "decode"
    B = batch
    return [
        ("tokens", (B,), "i32"),
        ("cache_lens", (B,), "i32"),
        ("adapter_ids", (B,), "i32"),
        ("adapter_on", (B,), "f32"),
        ("kb", (B, L, S, KH, HD), "f32"),
        ("vb", (B, L, S, KH, HD), "f32"),
        ("kr", (B, L, S, R), "f32"),
        ("vr", (B, L, S, R), "f32"),
    ]


def output_specs(cfg: ModelConfig, kind: str, batch: int = 1):
    L, S, KH, HD, R, V, d = (
        cfg.n_layers, cfg.s_max, cfg.n_kv_heads, cfg.head_dim, cfg.rank_max,
        cfg.vocab, cfg.d_model,
    )
    if kind == "prefill":
        C = cfg.chunk
        return [
            ("logits", (C, V), "f32"),
            ("kb_new", (L, C, KH, HD), "f32"),
            ("vb_new", (L, C, KH, HD), "f32"),
            ("kr_new", (L, C, R), "f32"),
            ("vr_new", (L, C, R), "f32"),
            ("km_new", (L, C, KH, HD), "f32"),
            ("vm_new", (L, C, KH, HD), "f32"),
            ("xs", (L, C, d), "f32"),
        ]
    B = batch
    return [
        ("logits", (B, V), "f32"),
        ("kb_new", (B, L, KH, HD), "f32"),
        ("vb_new", (B, L, KH, HD), "f32"),
        ("kr_new", (B, L, R), "f32"),
        ("vr_new", (B, L, R), "f32"),
        ("km_new", (B, L, KH, HD), "f32"),
        ("vm_new", (B, L, KH, HD), "f32"),
    ]
