#!/usr/bin/env python3
"""Bench-gate checker: assert metric comparisons over bench-http JSON reports.

Every CI bench gate used to be an inline ``python3 - <<'EOF'`` heredoc in
the workflow file; they are now declarative JSON specs under ``ci/gates/``
evaluated by this one script, so gates are testable, diffable, and share
one failure format.

Usage::

    python3 ci/check_bench.py check ci/gates/occupancy.json [--dir rust]
    python3 ci/check_bench.py selftest

A spec names the report files it reads and the checks to run::

    {
      "files":  {"on": "BENCH_on.json", "off": "BENCH_off.json"},
      "checks": [
        {"op": "eq", "left": "on.errors", "right": 0},
        {"op": "gt", "left": "on.engine.matched_rate",
                     "right": "off.engine.matched_rate"},
        {"op": "ge", "left": "on.ok", "right": "on.requests", "offset": -2},
        {"op": "count_ge",
         "list": "on.per_shard[*].avg_decode_batch", "gt": 1.0, "min": 2}
      ]
    }

Operand grammar (the ``left``/``right``/``list`` fields):

* JSON numbers and booleans are literals; ``{"lit": "affinity"}`` is a
  literal string (bare strings are always references).
* ``"on.engine.matched_rate"`` walks keys from a file alias.
* ``"on.per_shard[*].oom_drops"`` maps the tail over a list, yielding a
  list.
* ``sum(...)``, ``max(...)``, ``min(...)``, ``len(...)`` wrap a
  list-valued reference.
* ``"offset"`` (checks with ``left``/``right``) is added to the resolved
  right operand: ``ok >= requests - 2`` is ``offset: -2``.

Ops: ``eq ne gt lt ge le`` compare ``left`` vs ``right``; ``count_ge``
asserts at least ``min`` elements of ``list`` exceed ``gt``.
"""

import argparse
import json
import operator
import os
import sys

OPS = {
    "eq": operator.eq,
    "ne": operator.ne,
    "gt": operator.gt,
    "lt": operator.lt,
    "ge": operator.ge,
    "le": operator.le,
}

WRAPPERS = {"sum": sum, "max": max, "min": min, "len": len}


def walk(value, parts):
    """Walk key ``parts`` into ``value``, mapping over ``[*]`` segments.

    >>> walk({"a": {"b": 3}}, ["a", "b"])
    3
    >>> walk({"a": [{"n": 1}, {"n": 2}]}, ["a[*]", "n"])
    [1, 2]
    >>> walk({"a": [1, 2, 3]}, ["a[*]"])
    [1, 2, 3]
    >>> walk({"a": 1}, ["missing"])
    Traceback (most recent call last):
        ...
    KeyError: 'missing'
    """
    if not parts:
        return value
    head, tail = parts[0], parts[1:]
    if head.endswith("[*]"):
        seq = value[head[:-3]]
        if not isinstance(seq, list):
            raise TypeError(f"{head} is not a list")
        return [walk(item, tail) for item in seq]
    return walk(value[head], tail)


def resolve(expr, data):
    """Resolve an operand expression against the loaded reports.

    >>> data = {"r": {"ok": 7, "per_shard": [{"b": 1}, {"b": 2}]}}
    >>> resolve(3, data)
    3
    >>> resolve(True, data)
    True
    >>> resolve({"lit": "affinity"}, data)
    'affinity'
    >>> resolve("r.ok", data)
    7
    >>> resolve("r.per_shard[*].b", data)
    [1, 2]
    >>> resolve("sum(r.per_shard[*].b)", data)
    3
    >>> resolve("max(r.per_shard[*].b)", data)
    2
    >>> resolve("len(r.per_shard)", data)
    2
    """
    if isinstance(expr, dict):
        return expr["lit"]
    if not isinstance(expr, str):
        return expr
    for name, fn in WRAPPERS.items():
        if expr.startswith(name + "(") and expr.endswith(")"):
            inner = resolve(expr[len(name) + 1 : -1], data)
            # len() of a plain dict/list reference works too
            return fn(inner)
    return walk(data, expr.split("."))


def run_check(check, data):
    """Evaluate one check; return (ok, detail string).

    >>> data = {"r": {"ok": 7, "req": 9, "s": [{"d": 0.5}, {"d": 1.5}]}}
    >>> run_check({"op": "ge", "left": "r.ok", "right": "r.req",
    ...            "offset": -2}, data)
    (True, 'ge: r.ok (7) vs r.req - 2 (7)')
    >>> run_check({"op": "eq", "left": "r.ok", "right": 8}, data)[0]
    False
    >>> run_check({"op": "count_ge", "list": "r.s[*].d", "gt": 1.0,
    ...            "min": 2}, data)
    (False, 'count_ge: 1 of r.s[*].d ([0.5, 1.5]) > 1.0, need >= 2')
    """
    op = check["op"]
    if op == "count_ge":
        values = resolve(check["list"], data)
        bar, need = check["gt"], check["min"]
        n = sum(1 for v in values if v > bar)
        detail = f"count_ge: {n} of {check['list']} ({values}) > {bar}, need >= {need}"
        return n >= need, detail
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}")
    left = resolve(check["left"], data)
    right = resolve(check["right"], data)
    shown = f"{check['right']}"
    if "offset" in check:
        right += check["offset"]
        shown += f" {check['offset']:+d}".replace("+", "+ ").replace("-", "- ")
    detail = f"{op}: {check['left']} ({left}) vs {shown} ({right})"
    return OPS[op](left, right), detail


def run_spec(spec, base_dir):
    """Load the spec's files and run every check; return failure list."""
    data = {}
    for alias, path in spec["files"].items():
        with open(os.path.join(base_dir, path)) as fh:
            data[alias] = json.load(fh)
    failures = []
    for check in spec["checks"]:
        try:
            ok, detail = run_check(check, data)
        except Exception as exc:  # unresolvable ref = a broken gate: fail loudly
            ok, detail = False, f"{check.get('op')}: error resolving {check}: {exc!r}"
        mark = "ok " if ok else "FAIL"
        why = f"  # {check['why']}" if "why" in check else ""
        print(f"  [{mark}] {detail}{why}")
        if not ok:
            failures.append(detail)
    return failures


def cmd_check(args):
    with open(args.spec) as fh:
        spec = json.load(fh)
    print(f"{args.spec}: {len(spec['checks'])} checks over {sorted(spec['files'])}")
    failures = run_spec(spec, args.dir)
    if failures:
        print(f"{args.spec}: {len(failures)} check(s) FAILED", file=sys.stderr)
        return 1
    print(f"{args.spec}: all checks passed")
    return 0


def cmd_selftest(_args):
    """Doctest the resolver/comparator, then run a known-answer spec."""
    import doctest
    import tempfile

    results = doctest.testmod(sys.modules[__name__], verbose=False)
    if results.failed:
        print(f"selftest: {results.failed} doctest(s) failed", file=sys.stderr)
        return 1
    print(f"selftest: {results.attempted} doctests passed")

    # end-to-end: a fake A/B report pair through a spec exercising every op
    on = {
        "ok": 10, "requests": 10, "errors": 0, "gang": True,
        "route": "affinity",
        "engine": {"matched_rate": 0.8, "computed_prompt_tokens": 100},
        "per_shard": [{"d": 2.0, "b": 4}, {"d": 0.2, "b": 4}],
    }
    off = {
        "ok": 9, "requests": 10, "errors": 1, "gang": False,
        "route": "affinity",
        "engine": {"matched_rate": 0.5, "computed_prompt_tokens": 200},
        "per_shard": [{"d": 1.1, "b": 5}, {"d": 1.2, "b": 3}],
    }
    spec = {
        "files": {"on": "on.json", "off": "off.json"},
        "checks": [
            {"op": "eq", "left": "on.errors", "right": 0},
            {"op": "ne", "left": "off.errors", "right": 0},
            {"op": "eq", "left": "on.ok", "right": "on.requests"},
            {"op": "ge", "left": "off.ok", "right": "off.requests", "offset": -2},
            {"op": "eq", "left": "on.gang", "right": True},
            {"op": "eq", "left": "on.route", "right": {"lit": "affinity"}},
            {"op": "gt", "left": "on.engine.matched_rate",
             "right": "off.engine.matched_rate"},
            {"op": "lt", "left": "on.engine.computed_prompt_tokens",
             "right": "off.engine.computed_prompt_tokens"},
            {"op": "le", "left": "on.errors", "right": "off.errors"},
            {"op": "eq", "left": "sum(on.per_shard[*].b)",
             "right": "sum(off.per_shard[*].b)"},
            {"op": "gt", "left": "max(on.per_shard[*].d)",
             "right": "max(off.per_shard[*].d)"},
            {"op": "eq", "left": "len(on.per_shard)", "right": 2},
            {"op": "count_ge", "list": "off.per_shard[*].d", "gt": 1.0, "min": 2},
        ],
    }
    bad = {"op": "lt", "left": "on.ok", "right": 5}
    with tempfile.TemporaryDirectory() as tmp:
        for name, report in (("on.json", on), ("off.json", off)):
            with open(os.path.join(tmp, name), "w") as fh:
                json.dump(report, fh)
        if run_spec(spec, tmp):
            print("selftest: passing spec reported failures", file=sys.stderr)
            return 1
        spec["checks"] = [bad]
        if not run_spec(spec, tmp):
            print("selftest: failing spec reported success", file=sys.stderr)
            return 1
    print("selftest: ok")
    return 0


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = parser.add_subparsers(dest="cmd", required=True)
    check = sub.add_parser("check", help="evaluate a gate spec")
    check.add_argument("spec", help="path to the gate spec JSON")
    check.add_argument(
        "--dir", default=".", help="directory the spec's report paths are relative to"
    )
    check.set_defaults(fn=cmd_check)
    selftest = sub.add_parser("selftest", help="doctests + known-answer run")
    selftest.set_defaults(fn=cmd_selftest)
    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
