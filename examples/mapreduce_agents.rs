//! MapReduce workflow on the real PJRT engine: 6 mapper agents fork the
//! same shared context in parallel (the paper's broadcast-redundancy case,
//! Fig. 2b) and a reducer joins their outputs.
//!
//!   make artifacts && cargo run --release --example mapreduce_agents

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig};
use forkkv::engine::Engine;
use forkkv::exec::PjrtExecutor;
use forkkv::workload::{WorkflowDriver, WorkloadSpec};

fn run(policy: CachePolicy) -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts/llama3-8b-sim");
    let exec = PjrtExecutor::load(dir)?;
    let cfg = EngineConfig {
        policy,
        cache: CacheConfig { page_tokens: 16, budget_bytes: 24 << 20 },
        seed: 10,
        ..EngineConfig::default()
    };
    let mut engine = Engine::new(cfg, Box::new(exec))?;

    let mut spec = WorkloadSpec::mapreduce6("loogle", 2);
    spec.n_requests = 4;
    let mut driver = WorkflowDriver::new(spec);

    let t0 = std::time::Instant::now();
    engine.run_driver(&mut driver)?;
    println!(
        "{:<8} tasks={} (6 mappers + 1 reducer per request) tasks/s={:.2} wall={:.1}s hit={:.2} partial={:.2} mem {:.1}MB base / {:.2}MB res",
        policy.name(),
        driver.tasks_done(),
        driver.throughput_tasks_per_s(),
        t0.elapsed().as_secs_f64(),
        engine.metrics.hit_rate(),
        engine.metrics.hit_partial_tokens as f64 / engine.metrics.prompt_tokens as f64,
        engine.metrics.base_pool_bytes.max() / 1048576.0,
        engine.metrics.res_pool_bytes.max() / 1048576.0,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/llama3-8b-sim/manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    println!("# MapReduce broadcast fan-out, real PJRT execution");
    run(CachePolicy::Disaggregated)?;
    run(CachePolicy::UnifiedPerAdapter)?;
    Ok(())
}
