//! End-to-end ReAct workflow driver on the REAL PJRT engine (the
//! serving-paper validation run recorded in EXPERIMENTS.md §E2E):
//! a persistent 4-agent pipeline over a shared context serves a stream of
//! requests; we compare ForkKV against the prefix-caching baseline on
//! identical workloads and report throughput / TTFT / hit rates.
//!
//!   make artifacts && cargo run --release --example react_agents

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig};
use forkkv::engine::Engine;
use forkkv::exec::PjrtExecutor;
use forkkv::workload::{WorkflowDriver, WorkloadSpec};

fn run(policy: CachePolicy, budget_mb: usize) -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts/llama3-8b-sim");
    let exec = PjrtExecutor::load(dir)?;
    let cfg = EngineConfig {
        policy,
        cache: CacheConfig { page_tokens: 16, budget_bytes: budget_mb << 20 },
        seed: 9,
        ..EngineConfig::default()
    };
    let mut engine = Engine::new(cfg, Box::new(exec))?;

    // small real-mode geometry (artifacts are compiled for s_max=768):
    // 2 pipelines x 4 agents, 6 requests streaming through
    let mut spec = WorkloadSpec::react4("loogle", 2);
    spec.n_requests = 6;
    let mut driver = WorkflowDriver::new(spec);

    let t0 = std::time::Instant::now();
    engine.run_driver(&mut driver)?;
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "{:<8} tasks={} tasks/s={:.2} (virtual) wall={:.1}s hit={:.2} partial={:.2} batch={:.1} ttft p50={:.0}ms",
        policy.name(),
        driver.tasks_done(),
        driver.throughput_tasks_per_s(),
        wall,
        engine.metrics.hit_rate(),
        engine.metrics.hit_partial_tokens as f64 / engine.metrics.prompt_tokens as f64,
        engine.metrics.avg_decode_batch(),
        driver.ttft_us.percentile(50.0) / 1000.0,
    );
    engine.check_quiescent().map_err(|e| anyhow::anyhow!(e))?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/llama3-8b-sim/manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    println!("# ReAct pipeline, real PJRT execution, llama3-8b-sim");
    run(CachePolicy::Disaggregated, 24)?;
    run(CachePolicy::UnifiedPerAdapter, 24)?;
    Ok(())
}
